import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count at first init, and the production meshes need 512 placeholder devices
(single-pod 16×16 = 256 used as a sub-mesh, multi-pod 2×16×16 = 512).

Per cell this script:
  1. builds the model + abstract state (ShapeDtypeStructs, no allocation),
  2. attaches in/out shardings from :mod:`repro.distributed.sharding`,
  3. ``jit(...).lower(...).compile()`` — sharding mismatches, unsupported
     collectives or compile-time OOM are failures,
  4. prints ``memory_analysis()`` (does it fit 16 GB/chip?) and
     ``cost_analysis()`` (FLOPs / bytes for §Roofline),
  5. emits the 3-term roofline row (single-pod mesh only, per the spec).

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out results/dryrun.json
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.compat import cost_analysis_dict, set_mesh
from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.models import transformer as _tf
from repro.optim import AdamW, AdamWConfig
from repro.roofline import model_flops, roofline
from repro.train.step import build_train_step, init_state_abstract, state_shardings

__all__ = ["run_cell", "main"]

#: Per-shape train microbatch defaults (memory-bounded baseline).
TRAIN_MICROBATCHES = 4

#: Archs whose optimizer state needs FSDP sharding to fit 16 GB/chip.
#: fp32 AdamW state = 12 bytes/param over the 16-way model axis: 7B ⇒ 5.3 GB
#: (fits), 15B ⇒ 11.3 GB (fits, tight), 52B/773B ⇒ 39/580 GB (need FSDP).
#: FSDP costs data-axis collectives on the contracted weight dims (§Perf
#: cell-A evidence), so it is enabled only where capacity forces it.
FSDP_ARCHS = {
    "jamba-v0.1-52b",
    "llama4-maverick-400b-a17b",
}


def _sds(abstract, shardings):
    """ShapeDtypeStructs carrying shardings (lower() inputs, no allocation)."""
    return jax.tree.map(
        lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh),
        abstract,
        shardings,
    )


def _count_params_abstract(model) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(model.init_abstract()))


def _active_fraction(cfg) -> float:
    """active/total parameter fraction (MoE expert down-weighting)."""
    if cfg.moe is None:
        return 1.0
    # expert stacks dominate; approximate with exact per-leaf accounting
    import numpy as np

    total = 0
    active = 0
    model = Model(cfg)
    flat = jax.tree_util.tree_flatten_with_path(model.init_abstract())[0]
    from repro.distributed.sharding import path_of

    for kp, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        path = path_of(kp)
        if any(s in path for s in ("w_gate/", "w_up/", "w_down/")) and "ffn/" in path:
            active += int(n * cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    return active / total


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    microbatches: int = TRAIN_MICROBATCHES,
    fsdp: Optional[bool] = None,
    cross_pod: str = "auto",
    mesh=None,
    overrides: Optional[Dict[str, Any]] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    """Lower+compile one cell; returns the record for EXPERIMENTS.md."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return {
            "arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
            "status": "SKIP", "reason": "pure full-attention arch (DESIGN.md §5)",
        }
    if fsdp is None:
        fsdp = arch in FSDP_ARCHS
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    pod_size = n_devices // mesh.shape.get("pod", 1)
    model = Model(cfg)
    t0 = time.time()

    with set_mesh(mesh):
        if shape.kind == "train":
            opt = AdamW(AdamWConfig())
            n_pods = mesh.shape.get("pod", 0) if cross_pod != "auto" else 0
            state_abs = init_state_abstract(model, opt, n_pods=n_pods)
            st_sh = state_shardings(state_abs, mesh, fsdp=fsdp)
            batch_abs = {
                k: jax.ShapeDtypeStruct(s, d)
                for k, (s, d) in model.input_shapes(shape).items()
            }
            b_sh = batch_shardings(batch_abs, mesh)
            step = build_train_step(
                model, opt, mesh, microbatches=microbatches, loss_chunk=512,
                cross_pod=cross_pod,
            )
            lowered = step.lower(_sds(state_abs, st_sh), _sds(batch_abs, b_sh))
            tokens = shape.global_batch * shape.seq_len
            kind = "train"
        elif shape.kind == "prefill":
            params_abs = model.init_abstract()
            p_sh = param_shardings(params_abs, mesh, fsdp=fsdp)
            batch_abs = {
                k: jax.ShapeDtypeStruct(s, d)
                for k, (s, d) in model.input_shapes(shape).items()
            }
            b_sh = batch_shardings(batch_abs, mesh)
            serve_fn = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=shape.seq_len)
            )
            lowered = serve_fn.lower(_sds(params_abs, p_sh), _sds(batch_abs, b_sh))
            tokens = shape.global_batch * shape.seq_len
            kind = "serve"
        else:  # decode
            params_abs = model.init_abstract()
            p_sh = param_shardings(params_abs, mesh, fsdp=fsdp)
            cache_abs = jax.eval_shape(
                lambda: model.init_decode_cache(shape.global_batch, shape.seq_len)
            )
            c_sh = cache_shardings(cache_abs, mesh, batch=shape.global_batch)
            tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            serve_fn = jax.jit(model.decode_step, static_argnums=())
            lowered = serve_fn.lower(
                _sds(params_abs, p_sh), _sds(cache_abs, c_sh), tok_abs, pos_abs
            )
            tokens = shape.global_batch  # one new token per row
            kind = "serve"

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()

    n_params = _count_params_abstract(model)
    n_active = int(n_params * _active_fraction(cfg))
    mf = model_flops(n_params, n_active, tokens, kind=("train" if kind == "train" else "serve"))
    from repro.roofline.analytic import cell_bytes, cell_flops

    af = cell_flops(cfg, shape, moe_block=getattr(cfg, "moe_block", 0))
    ab = cell_bytes(
        cfg, shape, n_params=n_params, n_devices=n_devices,
        fsdp=fsdp, tp=mesh.shape["model"],
    )
    rep = roofline(
        cost=cost,
        hlo_text=hlo,
        n_devices=n_devices,
        pod_size=pod_size if multi_pod else 0,
        model_flops_total=mf,
        analytic_flops_total=af,
        analytic_bytes_per_chip=ab,
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK",
        "fsdp": fsdp,
        "microbatches": microbatches if shape.kind == "train" else 0,
        "n_params": n_params,
        "n_active": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "mem": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_est_gb": (
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            )
            / 1e9,
        },
        **rep,
    }
    if verbose:
        print(
            f"[{record['mesh']}] {arch:26s} {shape_name:12s} "
            f"compile={t_compile:6.1f}s peak={record['mem']['peak_est_gb']:7.2f}GB "
            f"t_comp={rep['t_compute_s']:.3e} t_mem={rep['t_memory_s']:.3e} "
            f"t_coll={rep['t_collective_s']:.3e} -> {rep['bottleneck']}"
        )
        print("  memory_analysis:", mem)
        print(
            "  cost_analysis: flops=%.3e bytes=%.3e"
            % (float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0)))
        )
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one architecture (default: all)")
    ap.add_argument("--shape", default=None, help="one shape cell (default: all)")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true", help="2×16×16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=TRAIN_MICROBATCHES)
    ap.add_argument("--out", default=None, help="write records to this JSON file")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    failures = 0
    for multi in meshes:
        for a in archs:
            for s in shapes:
                try:
                    rec = run_cell(a, s, multi_pod=multi, microbatches=args.microbatches)
                except Exception as exc:  # noqa: BLE001 — report, keep sweeping
                    traceback.print_exc()
                    rec = {
                        "arch": a, "shape": s,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": "FAIL", "error": f"{type(exc).__name__}: {exc}",
                    }
                    failures += 1
                records.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(records, fh, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    ok = sum(1 for r in records if r["status"] == "OK")
    skip = sum(1 for r in records if r["status"] == "SKIP")
    print(f"dry-run: {ok} OK, {skip} SKIP, {failures} FAIL / {len(records)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
