"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the local device set (CPU container: smoke-scale configs;
TPU fleet: the production mesh), wiring together every substrate:

    config → model → data pipeline → sharded train step → SCISPACE
    checkpointing (local-write + MEU) → fault-tolerant loop.

Example (CPU, ~100M-param quickstart is examples/train_end_to_end.py):
    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma2-2b --smoke --steps 50 --mesh 1,1 --global-batch 8
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs import SHAPES, get_config, smoke_variant
from repro.core import Collaboration
from repro.data import ShardedPipeline, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models import encdec as _encdec
from repro.optim import AdamW, AdamWConfig
from repro.train import CheckpointManager, Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1,1", help="data,model[,pod-major] e.g. 2,2 or 2,2,2")
    ap.add_argument("--cross-pod", default="auto", choices=["auto", "manual", "compressed"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--run", default="cli-run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = Model(cfg)

    shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(shape)
    opt = AdamW(
        AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)
    )
    frames = None
    patches = None
    if cfg.is_encdec:
        frames = (_encdec.enc_len_for(cfg, args.seq_len), cfg.frontend_dim)
    if cfg.frontend == "vision":
        patches = (cfg.frontend_tokens, cfg.frontend_dim)
    pipe = ShardedPipeline(
        SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len, period=16, vocab_eff=256),
        global_batch=args.global_batch,
        frames_shape=frames,
        patches_shape=patches,
    )

    ckpt = None
    if args.ckpt_every:
        collab = Collaboration()
        collab.add_datacenter("pod0", n_dtns=2)
        ckpt = CheckpointManager(collab, run=args.run, home_dc="pod0")

    trainer = Trainer(
        model,
        opt,
        mesh,
        pipe,
        TrainerConfig(
            microbatches=args.microbatches,
            loss_chunk=min(args.seq_len, 256),
            cross_pod=args.cross_pod,
            ckpt_every=args.ckpt_every,
        ),
        ckpt=ckpt,
        seed=args.seed,
    )
    result = trainer.run(args.steps)
    losses = [m["loss"] for m in trainer.metrics_log if "loss" in m]
    print(json.dumps({**result, "first_loss": losses[0], "last_loss": losses[-1]}, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
