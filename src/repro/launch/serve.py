"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the batched prefill/decode engine with continuous batching and runs a
synthetic request stream, reporting token throughput.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.serve import ServeConfig, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default=None, help="data,model e.g. 2,2 (default: no mesh)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    mesh = make_mesh(tuple(int(x) for x in args.mesh.split(","))) if args.mesh else None

    eng = ServeEngine(
        model,
        params,
        ServeConfig(
            max_len=args.max_len, slots=args.slots,
            temperature=args.temperature, eos_token=-1, seed=args.seed,
        ),
        mesh=mesh,
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        eng.submit(rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24))), args.max_new)
        for _ in range(args.requests)
    ]
    stats = eng.run_until_drained(reqs)
    assert all(r.done for r in reqs)
    print(json.dumps(stats, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
