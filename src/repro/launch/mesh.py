"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches see the plain 1-device CPU.

Mesh shapes (assignment spec):
- single-pod:  (data=16, model=16)            = 256 chips (one v5e pod)
- multi-pod:   (pod=2, data=16, model=16)     = 512 chips (2 pods over DCN)

The ``pod`` axis is the slow (DCN) axis — collectives on it are what the
SCISPACE-style hierarchical schedules minimize.  Axis order is
pod → data → model so the fastest-varying mesh dim (model/TP) maps to
ICI-adjacent devices.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "DEFAULT_SINGLE_POD", "DEFAULT_MULTI_POD"]

DEFAULT_SINGLE_POD: Tuple[int, ...] = (16, 16)
DEFAULT_MULTI_POD: Tuple[int, ...] = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (tests use tiny shapes like (2, 2))."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)
