"""Launchers: production mesh, multi-pod dry-run, train/serve CLIs."""

from .mesh import make_mesh, make_production_mesh

__all__ = ["make_mesh", "make_production_mesh"]
