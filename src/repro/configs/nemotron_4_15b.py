"""nemotron-4-15b — dense GQA with squared-ReLU MLP [arXiv:2402.16819].

Assigned: 32L, d_model=6144, 48H (GQA kv=8), d_ff=24576, vocab=256000.
Nemotron-4 signature: squared-ReLU activation, RoPE, no biases, untied
input/output embeddings, LayerNorm.
"""

from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    d_model=6144,
    n_layers=32,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    vocab_size=256000,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    activation="relu2",
    norm="layernorm",
    tie_embeddings=False,
)
