"""Config registry: the ten assigned architectures + the four shape cells.

Selection surface for every launcher/benchmark: ``--arch <id>`` resolves
through :func:`get_config`; :func:`applicable_shapes` encodes the
skip rules from the assignment (long_500k needs a sub-quadratic arch).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from .base import (
    LayerSpec,
    MambaSpec,
    ModelConfig,
    MoESpec,
    RWKVSpec,
    ShapeConfig,
    SHAPES,
    smoke_variant,
)
from .codeqwen1_5_7b import CONFIG as _codeqwen
from .gemma2_2b import CONFIG as _gemma2
from .internvl2_2b import CONFIG as _internvl2
from .jamba_v0_1_52b import CONFIG as _jamba
from .llama4_maverick_400b import CONFIG as _llama4
from .nemotron_4_15b import CONFIG as _nemotron
from .olmoe_1b_7b import CONFIG as _olmoe
from .rwkv6_7b import CONFIG as _rwkv6
from .seamless_m4t_medium import CONFIG as _seamless
from .stablelm_3b import CONFIG as _stablelm

__all__ = [
    "ARCHS",
    "SHAPES",
    "get_config",
    "applicable_shapes",
    "all_cells",
    "smoke_variant",
    "ModelConfig",
    "ShapeConfig",
    "LayerSpec",
    "MoESpec",
    "MambaSpec",
    "RWKVSpec",
]

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _jamba,
        _codeqwen,
        _gemma2,
        _nemotron,
        _stablelm,
        _rwkv6,
        _seamless,
        _llama4,
        _olmoe,
        _internvl2,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape cells this architecture actually runs.

    ``long_500k`` requires sub-quadratic attention (SSM/hybrid/windowed);
    pure full-attention archs record a SKIP for it (DESIGN.md §5).
    Every assigned arch has a decoder, so decode shapes always apply.
    """
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(shape)
    return out


def all_cells() -> List[Tuple[ModelConfig, ShapeConfig]]:
    """Every runnable (arch × shape) cell, in registry order."""
    return [(cfg, shape) for cfg in ARCHS.values() for shape in applicable_shapes(cfg)]
