"""The paper's own evaluation testbed (§IV-B, Table I) as a config.

This is the SCISPACE-native configuration — not an LM architecture but the
collaboration fabric the paper measures: 2 geo-distributed data centers,
Lustre per DC, 2 DTNs each (4 Lustre client nodes total), 1–24
collaborators over IB EDR.  `benchmarks.common.make_collab` instantiates
it; the constants there map IB/Lustre characteristics onto the container's
simulated channels (DESIGN.md §2, §8).
"""

from dataclasses import dataclass, field
from typing import Tuple

from repro.core.datapath import (
    CHUNK_CACHE_BYTES,
    DATA_LANES,
    STRIPE_BYTES,
)
from repro.core.cluster import REPLICA_N
from repro.core.leases import DEFAULT_LEASE_TTL_S
from repro.core.plane import BREAKER_COOLDOWN_S, BREAKER_THRESHOLD, WRITE_QUORUM
from repro.core.query import SUMMARY_BITS
from repro.core.replication import (
    COMPACT_WINDOW,
    PUMP_MAX_AGE_S,
    PUMP_MAX_PENDING,
    RECONCILE_TIMEOUT_S,
    WB_MAX_AGE_S,
    WB_MAX_PENDING,
)
from repro.core.telemetry import HIST_BUCKETS, TRACE_BUFFER_SPANS, TRACE_ENABLED

__all__ = ["TESTBED"]


@dataclass(frozen=True)
class TestbedConfig:
    n_datacenters: int = 2
    dtns_per_dc: int = 2                 # Table I: 4 DTN nodes total
    collaborators: Tuple[int, ...] = (1, 4, 8, 16, 24)
    network: str = "Infiniband EDR (100 Gb/s)"
    pfs: str = "Lustre (2×MDS, 2×OSS, 11×7.2TB RAID-0 OSTs per DC)"
    # evaluation datasets
    synthetic_bytes: int = 375 << 30     # IOR, 375 GB
    real_dataset: str = "MODIS-Aqua ocean surface, 116 GB / 4600 HDF5 files"
    block_sizes: Tuple[int, ...] = (4 << 10, 16 << 10, 64 << 10, 256 << 10, 512 << 10)
    attr_counts: Tuple[int, ...] = (5, 20)
    hit_ratios: Tuple[float, ...] = (0.0, 0.25, 0.5, 0.75, 1.0)
    # write-back flush thresholds (the AsyncIndexer-style count/age pair for
    # the plane's crash-recoverable WriteBackJournal).  Defaults come from
    # core/replication.py so the two never drift; benchmarks pass TESTBED
    # values through Workspace(wb_max_pending=..., wb_max_age_s=...)
    wb_max_pending: int = WB_MAX_PENDING
    wb_max_age_s: float = WB_MAX_AGE_S
    # replication-tier lag bounds: a ReplicaPump drains its DTN's log when
    # either fires, so replicas trail origins by at most this much
    # (Collaboration.start_replication(max_pending=..., max_age_s=...))
    replication_max_pending: int = PUMP_MAX_PENDING
    replication_max_age_s: float = PUMP_MAX_AGE_S
    # planner merge fan-in: the scatter-gather tree-merge folds at most this
    # many per-shard partial results per level (scaling past 8 DTNs)
    query_merge_group: int = 8
    # wire-path acceleration knobs (all honored by
    # Collaboration.start_replication(batch_limit=..., adaptive_batch=...)
    # and Collaboration.add_datacenter(summary_bits=...)):
    # - compact_window: max records a pump drains (and path-compacts) per
    #   window; also the AdaptiveBatcher's starting point
    # - summary_bits: width of each discovery shard's bloom summary (4096
    #   bits ≈ 512 B per shard per reply — noise next to the rows it prunes)
    # - adaptive_batch: let drain-latency feedback resize the window inside
    #   [32, 4096] instead of the fixed compact_window
    compact_window: int = COMPACT_WINDOW
    summary_bits: int = SUMMARY_BITS
    adaptive_batch: bool = False
    # data-plane knobs (all honored by Workspace(stripe_bytes=..., ...)):
    # - stripe_bytes: cross-DC transfers are chopped into chunks of this
    #   size and dealt round-robin over the lane pool (0 = single-shot)
    # - data_lanes: concurrent lanes per DC link; lanes share the link's
    #   aggregate gbps but each carries its own window-bound stream and
    #   overlaps latency + PFS store time (GridFTP-style parallel streams)
    # - chunk_cache_bytes: client-side LRU chunk cache for remote-DC reads,
    #   kept consistent via the path-hash InvalidationBus + epoch fences
    #   (0 disables caching)
    # - readahead: asynchronous scidata payload prefetch in directory order
    stripe_bytes: int = STRIPE_BYTES
    data_lanes: int = DATA_LANES
    chunk_cache_bytes: int = CHUNK_CACHE_BYTES
    readahead: bool = True
    # fault-plane knobs (core/faults.py, core/rpc.py RetryPolicy, and the
    # plane's CircuitBreaker; all honored by Workspace(retry=..., ...)):
    # - retry_enabled: build Workspaces with a RetryPolicy so every RPC and
    #   striped transfer retries with exponential backoff + decorrelated
    #   jitter instead of failing fast; mutating RPCs carry idempotency
    #   tokens so a retried write or replication drain applies exactly once
    #   (server-side request-id dedup window in RpcServer.handle)
    # - retry_max_attempts / retry_base_s / retry_cap_s: backoff shape —
    #   sleep ~ uniform(base, 3*prev) capped at cap_s (decorrelated jitter)
    # - retry_deadline_s: per-call deadline; no retry is attempted that
    #   could not complete before it
    # - retry_budget: per-client cap on total retries, so a melting fabric
    #   is not amplified by retry storms
    # - breaker_threshold: consecutive unavailability failures before a
    #   DTN's circuit breaker opens (closed -> open -> half-open probe)
    # - breaker_cooldown_s: how long an open breaker waits before admitting
    #   the single half-open probe
    # - fault_plan: name of a canned FaultPlan from core.faults.CANNED_PLANS
    #   ("drops" | "flaky" | "crash" | "chaos" | "quorum" | "lease-expiry";
    #   "" = none) for fault-matrix smoke runs — see benchmarks/fig13_faults.py
    #   and benchmarks/fig14_quorum.py for the how-to
    retry_enabled: bool = True
    retry_max_attempts: int = 4
    retry_base_s: float = 0.002
    retry_cap_s: float = 0.1
    retry_deadline_s: float = 2.0
    retry_budget: int = 1000
    breaker_threshold: int = BREAKER_THRESHOLD
    breaker_cooldown_s: float = BREAKER_COOLDOWN_S
    fault_plan: str = ""
    # partition-tolerant write knobs (core/leases.py, plane.quorum_create,
    # Collaboration.reconcile; honored by Workspace(write_quorum=...,
    # lease_ttl_s=...)):
    # - replica_n: size of a path's replica set (owner + ring successors) —
    #   the membership leases are granted over and quorums counted against
    # - write_quorum: members (coordinator included) that must durably apply
    #   a degraded write before it is acknowledged
    # - lease_ttl_s: per-prefix write-lease TTL; a holder renews at 25%
    #   remaining, a successor's grant fences all older tokens out
    # - reconcile_timeout_s: bound on one anti-entropy pass after heal
    #   (Collaboration.reconcile(timeout_s=...))
    replica_n: int = REPLICA_N
    write_quorum: int = WRITE_QUORUM
    lease_ttl_s: float = DEFAULT_LEASE_TTL_S
    reconcile_timeout_s: float = RECONCILE_TIMEOUT_S
    # telemetry-plane knobs (core/telemetry.py; honored by
    # Collaboration.add_datacenter(trace_enabled=..., ...) and
    # Workspace(trace_enabled=..., ...)):
    # - trace_enabled: mint trace/span IDs at every Workspace entry point and
    #   carry them in RPC envelopes so each hop records a causally-linked
    #   span; off turns every trace entry point into a near-free no-op
    #   (benchmarks/fig15_telemetry.py gates the on-vs-off overhead <= 5%)
    # - trace_buffer_spans: per-node bounded span buffer depth (oldest spans
    #   age out first; Collaboration.collect_trace stitches across buffers)
    # - hist_buckets: log2 bucket count for registry latency/byte histograms
    #   (rpc.call_seconds, datapath.transfer_seconds/_bytes)
    trace_enabled: bool = TRACE_ENABLED
    trace_buffer_spans: int = TRACE_BUFFER_SPANS
    hist_buckets: int = HIST_BUCKETS


TESTBED = TestbedConfig()
