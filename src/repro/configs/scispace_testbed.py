"""The paper's own evaluation testbed (§IV-B, Table I) as a config.

This is the SCISPACE-native configuration — not an LM architecture but the
collaboration fabric the paper measures: 2 geo-distributed data centers,
Lustre per DC, 2 DTNs each (4 Lustre client nodes total), 1–24
collaborators over IB EDR.  `benchmarks.common.make_collab` instantiates
it; the constants there map IB/Lustre characteristics onto the container's
simulated channels (DESIGN.md §2, §8).
"""

from dataclasses import dataclass, field
from typing import Tuple

__all__ = ["TESTBED"]


@dataclass(frozen=True)
class TestbedConfig:
    n_datacenters: int = 2
    dtns_per_dc: int = 2                 # Table I: 4 DTN nodes total
    collaborators: Tuple[int, ...] = (1, 4, 8, 16, 24)
    network: str = "Infiniband EDR (100 Gb/s)"
    pfs: str = "Lustre (2×MDS, 2×OSS, 11×7.2TB RAID-0 OSTs per DC)"
    # evaluation datasets
    synthetic_bytes: int = 375 << 30     # IOR, 375 GB
    real_dataset: str = "MODIS-Aqua ocean surface, 116 GB / 4600 HDF5 files"
    block_sizes: Tuple[int, ...] = (4 << 10, 16 << 10, 64 << 10, 256 << 10, 512 << 10)
    attr_counts: Tuple[int, ...] = (5, 20)
    hit_ratios: Tuple[float, ...] = (0.0, 0.25, 0.5, 0.75, 1.0)


TESTBED = TestbedConfig()
