"""olmoe-1b-7b — fine-grained MoE, 64 experts top-8 [arXiv:2409.02060].

Assigned: 16L, d_model=2048, 16H (GQA kv=16 ⇒ MHA), d_ff=1024 per expert,
vocab=50304, MoE 64e top-8 on every layer.  OLMoE signature: QK-RMSNorm,
small experts, no shared expert, RMSNorm + SwiGLU.
"""

from .base import LayerSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    d_model=2048,
    n_layers=16,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    vocab_size=50304,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    activation="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    moe=MoESpec(n_experts=64, top_k=8, d_ff=1024),
    tie_embeddings=False,
)
