"""llama4-maverick-400b-a17b — MoE 128e top-1 with shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E].

Assigned (literal, treated as source of truth — see DESIGN.md §5 note):
48L, d_model=5120, 40H (GQA kv=8), d_ff=8192 per expert, vocab=202048,
MoE 128 experts top-1 + an always-on shared expert (Llama4 signature).
"""

from .base import LayerSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    d_model=5120,
    n_layers=48,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    vocab_size=202048,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    moe=MoESpec(n_experts=128, top_k=1, d_ff=8192, shared_expert=True),
    tie_embeddings=False,
)
