"""internvl2-2b — VLM: InternViT frontend + InternLM2 LM [arXiv:2404.16821].

Assigned: 24L, d_model=2048, 16H (GQA kv=8), d_ff=8192, vocab=92553.
Per the assignment the vision frontend (InternViT-300M) is a STUB:
``input_specs()`` supplies precomputed patch embeddings (1024-dim, 256
tokens per image) which are projected and spliced into the token stream;
the 24L InternLM2-1.8B-style backbone is implemented in full.
"""

from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    d_model=2048,
    n_layers=24,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    vocab_size=92553,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_dim=1024,
    frontend_tokens=256,
)
