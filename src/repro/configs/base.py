"""Config system: model architectures, input shapes, and run plans.

Every assigned architecture is a :class:`ModelConfig` built from a repeating
**layer pattern** (a tuple of :class:`LayerSpec`), which is how heterogeneous
stacks (Jamba's 1-attention-per-8, Gemma2's local/global alternation,
MoE-every-other-layer) are expressed while still compiling as a single
``lax.scan`` over pattern repeats ("units").  ``n_layers`` must be a multiple
of ``len(pattern)``.

Shapes are the four assigned input-shape cells; ``kind`` selects which step
function a cell lowers (``train`` → train_step, ``prefill``/``decode`` →
serve steps).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = [
    "MoESpec",
    "MambaSpec",
    "RWKVSpec",
    "LayerSpec",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "smoke_variant",
]


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden width
    shared_expert: bool = False   # Llama4-style always-on expert
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    capacity_factor: float = 1.25  # per-expert slots = ceil(S·K·cf/E)


@dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 ⇒ ceil(d_model/16)


@dataclass(frozen=True)
class RWKVSpec:
    head_dim: int = 64


@dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating pattern unit."""

    mixer: str  # 'attn' | 'attn_local' | 'mamba' | 'rwkv'
    ffn: str    # 'dense' | 'moe' | 'rwkv_cmix'

    def __post_init__(self):
        assert self.mixer in ("attn", "attn_local", "mamba", "rwkv"), self.mixer
        assert self.ffn in ("dense", "moe", "rwkv_cmix"), self.ffn


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|hybrid|ssm|encdec|vlm|audio
    d_model: int
    n_layers: int
    pattern: Tuple[LayerSpec, ...]
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0              # 0 ⇒ d_model // n_heads
    d_ff: int = 0
    activation: str = "swiglu"     # swiglu|gelu|relu2
    norm: str = "rmsnorm"          # rmsnorm|layernorm
    use_rope: bool = True
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # partial rotary (StableLM: 0.25)
    qkv_bias: bool = False
    qk_norm: bool = False          # OLMoE-style q/k RMSNorm
    attn_window: int = 0           # sliding window for 'attn_local' mixers
    attn_softcap: float = 0.0      # Gemma2 attention-logit softcap
    final_softcap: float = 0.0     # Gemma2 final-logit softcap
    post_block_norm: bool = False  # Gemma2 sandwich norms
    tie_embeddings: bool = True
    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    rwkv: Optional[RWKVSpec] = None
    # encoder-decoder (Seamless backbone): n_layers is the decoder depth
    n_enc_layers: int = 0
    # modality frontend stubs ([audio]/[vlm]): precomputed embeddings
    frontend: Optional[str] = None  # None|'vision'|'audio'
    frontend_dim: int = 0           # embedding dim delivered by the stub
    frontend_tokens: int = 0        # patches/frames per example
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # performance knobs (hillclimbing surface)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    ssm_chunk: int = 256
    moe_block: int = 0             # MoE dispatch block (0 ⇒ whole sequence)
    scan_layers: bool = True
    remat: str = "unit"            # 'none'|'unit'|'dots'
    remat_loss_chunk: bool = False # recompute logits chunks in backward
    seq_shard_activations: bool = False  # SP: residual stream S-sharded on 'model'
    gather_ce: bool = False        # legacy take_along_axis CE (baseline only)
    use_pallas: bool = False       # TPU deployment flag; CPU dry-run uses jnp path
    # capability flags
    sub_quadratic: bool = False    # eligible for long_500k

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern len {len(self.pattern)}"
        )
        if any(s.mixer in ("attn", "attn_local") for s in self.pattern):
            assert self.n_heads > 0 and self.n_kv_heads > 0
        if any(s.ffn == "moe" for s in self.pattern):
            assert self.moe is not None
        if any(s.mixer == "mamba" for s in self.pattern):
            assert self.mamba is not None
        if any(s.mixer == "rwkv" for s in self.pattern):
            assert self.rwkv is not None

    # -- derived ---------------------------------------------------------------
    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")


#: The assigned LM-transformer shape set (same four cells for every arch).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests.

    Keeps the pattern (so every mixer/ffn kind is exercised) but shrinks
    width, depth, vocab and expert count.
    """
    kw: Dict = dict(
        d_model=64,
        n_layers=len(cfg.pattern),   # one unit
        d_ff=128,
        vocab_size=512,
        dtype="float32",
        param_dtype="float32",
        attn_chunk_q=32,
        attn_chunk_kv=32,
        ssm_chunk=16,
        remat="none",
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1))
        kw["head_dim"] = 16
    if cfg.moe is not None:
        kw["moe"] = MoESpec(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff=64,
            shared_expert=cfg.moe.shared_expert,
        )
    if cfg.mamba is not None:
        kw["mamba"] = MambaSpec(d_state=8, d_conv=4, expand=2)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVSpec(head_dim=16)
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 1
    if cfg.frontend:
        kw["frontend_dim"] = 32
        kw["frontend_tokens"] = 8
    if cfg.attn_window:
        kw["attn_window"] = 16
    return cfg.replace(name=cfg.name + "-smoke", **kw)
