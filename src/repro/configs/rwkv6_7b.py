"""rwkv6-7b — attention-free RWKV-6 "Finch" [arXiv:2404.05892].

Assigned: 32L, d_model=4096, attention-free, d_ff=14336, vocab=65536.
Finch signature: data-dependent decay time-mix (WKV recurrence with
outer-product state), squared-ReLU channel-mix, head_dim=64.
O(1)-state decode ⇒ ``long_500k`` runs.
"""

from .base import LayerSpec, ModelConfig, RWKVSpec

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    d_model=4096,
    n_layers=32,
    pattern=(LayerSpec(mixer="rwkv", ffn="rwkv_cmix"),),
    vocab_size=65536,
    d_ff=14336,
    norm="layernorm",
    use_rope=False,
    rwkv=RWKVSpec(head_dim=64),
    sub_quadratic=True,
)
