"""stablelm-3b — dense with partial rotary embeddings [hf:stabilityai/stablelm-2-1_6b].

Assigned: 32L, d_model=2560, 32H (GQA kv=32 ⇒ MHA), d_ff=6912, vocab=50304.
StableLM-2 signature: partial RoPE (25% of head dim), LayerNorm, SwiGLU,
QKV biases, untied embeddings.
"""

from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    d_model=2560,
    n_layers=32,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    vocab_size=50304,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    activation="swiglu",
    norm="layernorm",
    rope_fraction=0.25,
    qkv_bias=True,
    tie_embeddings=False,
)
