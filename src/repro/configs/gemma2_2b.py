"""gemma2-2b — dense with local/global alternating attention [arXiv:2408.00118].

Assigned: 26L, d_model=2304, 8H (GQA kv=4), d_ff=9216, vocab=256000.
Gemma2 signature: alternating 4096-token sliding-window and global layers,
attention-logit softcap 50, final-logit softcap 30, sandwich (post-block)
RMSNorms, GeGLU MLP, head_dim=256, tied embeddings scaled by sqrt(d_model).

long_500k runs: half the layers are sliding-window (ring KV cache of 4096);
the global layers decode O(S) against their cache — recorded in DESIGN.md.
"""

from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    d_model=2304,
    n_layers=26,
    pattern=(
        LayerSpec(mixer="attn_local", ffn="dense"),
        LayerSpec(mixer="attn", ffn="dense"),
    ),
    vocab_size=256000,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    activation="gelu",
    norm="rmsnorm",
    attn_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_block_norm=True,
    tie_embeddings=True,
    sub_quadratic=True,   # windowed layers bound the quadratic term
)
