"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE [arXiv:2403.19887].

Assigned: 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536,
MoE 16 experts top-2.

Jamba's block is an 8-layer unit with exactly one attention layer (index 4)
and MoE replacing the MLP on every other layer (odd indices) — 1:7
attention:mamba ratio and e=2 MoE period, per the paper.  Mamba mixers make
the arch sub-quadratic: ``long_500k`` runs (attention layers decode O(S)
against their KV cache; the SSM state is O(1)).
"""

from .base import LayerSpec, MambaSpec, ModelConfig, MoESpec

_UNIT = tuple(
    LayerSpec(
        mixer="attn" if i == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_layers=32,
    pattern=_UNIT,
    vocab_size=65536,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=False,          # Jamba relies on Mamba for position information
    moe=MoESpec(n_experts=16, top_k=2, d_ff=14336),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,
)
