"""codeqwen1.5-7b — dense Qwen1.5-architecture code model [hf:Qwen/CodeQwen1.5-7B].

Assigned: 32L, d_model=4096, 32H (GQA kv=32 ⇒ MHA), d_ff=13440, vocab=92416.
Qwen1.5 signature: QKV biases, SwiGLU, RMSNorm, large RoPE base.
"""

from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    d_model=4096,
    n_layers=32,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    vocab_size=92416,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=False,
)
