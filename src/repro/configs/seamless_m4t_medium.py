"""seamless-m4t-medium — encoder-decoder multimodal backbone [arXiv:2308.11596].

Assigned: 12L, d_model=1024, 16H (GQA kv=16 ⇒ MHA), d_ff=4096, vocab=256206.
Per the assignment the modality frontend is a STUB: ``input_specs()``
supplies precomputed speech-frame embeddings (frontend_dim=1024) and the
backbone is the 12L encoder + 12L decoder transformer with cross-attention.
Full attention ⇒ long_500k is skipped (DESIGN.md §Arch-applicability);
decode shapes lower the enc-dec serve step (this is NOT encoder-only).
"""

from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    d_model=1024,
    n_layers=12,          # decoder depth
    n_enc_layers=12,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    vocab_size=256206,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    activation="gelu",
    norm="layernorm",
    frontend="audio",
    frontend_dim=1024,
)
