"""Dispatch layer: Pallas kernels ⇄ pure-jnp reference paths.

Models call these wrappers; ``use_pallas`` (from the ModelConfig) selects the
TPU kernels, otherwise the chunked pure-jnp twins in :mod:`repro.models` run
(CPU dry-runs, oracles).  On this CPU container Pallas executes in interpret
mode; on a real TPU ``interpret=False`` compiles to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .mamba_scan import mamba_scan_pallas
from .rwkv6_scan import wkv6_pallas

__all__ = ["attention", "wkv6", "mamba_scan", "INTERPRET"]

#: Flip to False on a real TPU deployment.
INTERPRET = True


def attention(
    q, k, v, *, causal=True, window=0, logit_softcap=0.0,
    chunk_q=512, chunk_kv=1024, q_offset=0, use_pallas=False,
):
    """[B,S,H,hd] × [B,T,Kv,hd]² → [B,S,H,hd]."""
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
            block_q=chunk_q, block_kv=chunk_kv, q_offset=q_offset,
            interpret=INTERPRET,
        )
    from repro.models.attention import flash_attention

    return flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        chunk_q=chunk_q, chunk_kv=chunk_kv, q_offset=q_offset,
    )


def wkv6(r, k, v, w, u, *, chunk=128, s0=None, use_pallas=False):
    """RWKV-6 recurrence.  Pallas path requires zero initial state."""
    if use_pallas and s0 is None:
        out = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=INTERPRET)
        return out, None
    from repro.models.rwkv6 import wkv_chunked

    return wkv_chunked(r, k, v, w, u, chunk=chunk, s0=s0)


def mamba_scan(u, delta, A, Bmat, Cmat, *, chunk=128, h0=None, use_pallas=False):
    """Selective scan.  Pallas path requires zero initial state."""
    if use_pallas and h0 is None:
        y = mamba_scan_pallas(u, delta, A, Bmat, Cmat, chunk=chunk, interpret=INTERPRET)
        return y, None
    from repro.models.mamba import ssm_chunked_scan

    return ssm_chunked_scan(u, delta, A, Bmat, Cmat, chunk=chunk, h0=h0)
