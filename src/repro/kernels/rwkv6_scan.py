"""Chunked RWKV-6 WKV recurrence for TPU (``pl.pallas_call`` + BlockSpecs).

TPU adaptation of the CUDA wkv6 kernel (DESIGN.md §6): the GPU kernel runs
one thread per channel and serializes over time; a mechanical port would
leave the MXU idle.  Instead the sequence is processed in **chunks**: the
O(C×C) state crosses chunk boundaries (the only sequential dependence) while
all intra-chunk work is dense [chunk, C]×[C, C] / [chunk, chunk] matmul-like
contractions — the SCISPACE theme of "keep bulk work local, move only the
small state" applied at the register/VMEM level.

Grid ``(B, H, n_chunks)`` with the chunk index innermost; the running state
S ∈ ℝ^{C×C} (f32) persists in VMEM scratch across chunk steps.  Per chunk:

    L_t   = cumsum(log w)                      (inclusive), Lx = L - log w
    inter = (r ∘ exp(Lx)) @ S                  [chunk, C] — MXU
    att[t,u] = Σ_i r_t,i · exp(Lx_t,i − L_u,i) · k_u,i   (u < t, strictly)
    diag[t]  = Σ_i r_t,i · u_i · k_t,i         (current-token bonus)
    out   = inter + att @ v + diag ∘ v
    S     ← exp(L_last) ∘ S + Σ_u exp(L_last − L_u) k_u ⊗ v_u

All exponentials have non-positive arguments (log w ≤ 0 and u ≤ t), so the
chunk math is stable at any chunk size — the same invariant the pure-jnp
twin :func:`repro.models.rwkv6.wkv_chunked` relies on.  VMEM per step:
~5·chunk·C + chunk² + C² floats (chunk=128, C=64 → ~0.3 MB).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_pallas"]


def _wkv_kernel(
    r_ref,   # [1, cs, 1, C]
    k_ref,   # [1, cs, 1, C]
    v_ref,   # [1, cs, 1, C]
    lw_ref,  # [1, cs, 1, C]  log-decay (≤ 0)
    u_ref,   # [1, C]         bonus for this head
    o_ref,   # [1, cs, 1, C]
    s_ref,   # VMEM [C, C] running state
    *,
    cs: int,
    C: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)    # [cs, C]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # [C]

    L = jnp.cumsum(lw, axis=0)                   # inclusive  L_t   [cs, C]
    Lx = L - lw                                  # exclusive  L_{t-1}

    # inter-chunk contribution through the carried state (MXU matmul)
    r_dec = r * jnp.exp(Lx)
    inter = jax.lax.dot_general(
        r_dec, s_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # [cs, C]

    # intra-chunk pairwise scores (strictly lower-triangular in t, u)
    rel = Lx[:, None, :] - L[None, :, :]         # [t, u, C]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    tri = (u_idx < t_idx)[..., None]             # u < t
    rel = jnp.where(tri, rel, -jnp.inf)
    att = jnp.einsum("ti,tui,ui->tu", r, jnp.exp(rel), k)     # [cs, cs]
    out = inter + jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)  # [cs, 1]
    out = out + diag * v

    # state update: S ← exp(L_T) ∘ S + Σ_u exp(L_T − L_u) k_u ⊗ v_u
    decay_all = jnp.exp(L[-1][None, :] - L)      # [cs, C] (≤ 1)
    s_new = jnp.exp(L[-1])[:, None] * s_ref[...] + jax.lax.dot_general(
        decay_all * k, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(
    r: jax.Array,  # [B, S, H, C]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # [B, S, H, C] decay in (0, 1)
    u: jax.Array,  # [H, C]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Pallas WKV; matches :func:`repro.kernels.ref.wkv6_ref` (zero initial state)."""
    B, S, H, C = r.shape
    cs = min(chunk, S)
    assert S % cs == 0, (S, cs)
    nc = S // cs

    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    kernel = functools.partial(_wkv_kernel, cs=cs, C=C)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, cs, 1, C), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, cs, 1, C), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, cs, 1, C), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, cs, 1, C), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, C), lambda b, h, ic: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, cs, 1, C), lambda b, h, ic: (b, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, C), r.dtype),
        scratch_shapes=[pltpu.VMEM((C, C), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out
