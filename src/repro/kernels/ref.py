"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *naive* formulations — materialized score matrices, plain
sequential recurrences — kept deliberately simple so they are obviously
correct.  Kernel tests sweep shapes/dtypes and ``assert_allclose`` the Pallas
outputs (interpret mode on CPU) against these; the chunked pure-jnp model
paths in :mod:`repro.models` are validated against the same oracles.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "wkv6_ref", "mamba_scan_ref"]

_BIG_NEG = -1e30


def attention_ref(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, T, Kv, hd]
    v: jax.Array,  # [B, T, Kv, hd]
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Full-softmax attention with an explicit [S, T] score matrix."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, S, Kv, G, hd).astype(jnp.float32)
    kh = k.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qh, kh) * scale
    if logit_softcap and logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = q_offset + jnp.arange(S)
    k_pos = jnp.arange(T)
    ok = jnp.ones((S, T), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(ok[None, None, None], s, _BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def wkv6_ref(
    r: jax.Array,  # [B, S, H, C]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # [B, S, H, C] decay in (0, 1)
    u: jax.Array,  # [H, C] current-token bonus
    *,
    s0: Optional[jax.Array] = None,  # [B, H, C, C]
) -> Tuple[jax.Array, jax.Array]:
    """Sequential RWKV-6 recurrence, one token at a time.

        out_t = r_t · (S_{t-1} + (u ∘ k_t) ⊗ v_t)
        S_t   = diag(w_t) S_{t-1} + k_t ⊗ v_t

    Returns (out [B,S,H,C], final state [B,H,C,C]).
    """
    B, S, H, C = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    state = jnp.zeros((B, H, C, C), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # [B, H, C] each
        kv = kt[..., :, None] * vt[..., None, :]            # [B,H,C,C]
        s_eff = s + uf[None, :, :, None] * kv
        out = jnp.einsum("bhi,bhij->bhj", rt, s_eff)
        s_new = wt[..., :, None] * s + kv
        return s_new, out

    state, outs = jax.lax.scan(
        step,
        state,
        (
            jnp.moveaxis(rf, 1, 0),
            jnp.moveaxis(kf, 1, 0),
            jnp.moveaxis(vf, 1, 0),
            jnp.moveaxis(wf, 1, 0),
        ),
    )
    out = jnp.moveaxis(outs, 0, 1)  # [B, S, H, C]
    return out.astype(r.dtype), state


def mamba_scan_ref(
    u: jax.Array,      # [B, S, di]
    delta: jax.Array,  # [B, S, di]  (already softplus'd)
    A: jax.Array,      # [di, ds]    (negative)
    Bmat: jax.Array,   # [B, S, ds]
    Cmat: jax.Array,   # [B, S, ds]
    *,
    h0: Optional[jax.Array] = None,  # [B, di, ds]
) -> Tuple[jax.Array, jax.Array]:
    """Sequential selective scan:

        h_t = exp(Δ_t A) ∘ h_{t-1} + (Δ_t u_t) B_t ;  y_t = C_t · h_t

    Returns (y [B,S,di], h_final [B,di,ds]).
    """
    B, S, di = u.shape
    ds = A.shape[1]
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    h = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        ut, dt, bt, ct = inp  # [B,di], [B,di], [B,ds], [B,ds]
        decay = jnp.exp(dt[..., None] * Af[None])           # [B,di,ds]
        drive = (dt * ut)[..., None] * bt[:, None, :]       # [B,di,ds]
        h_new = decay * h + drive
        y = jnp.einsum("bdn,bn->bd", h_new, ct)
        return h_new, y

    h, ys = jax.lax.scan(
        step,
        h,
        (
            jnp.moveaxis(uf, 1, 0),
            jnp.moveaxis(df, 1, 0),
            jnp.moveaxis(Bf, 1, 0),
            jnp.moveaxis(Cf, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1)  # [B, S, di]
    return y.astype(u.dtype), h
