"""Fused flash attention for TPU (``pl.pallas_call`` + explicit BlockSpecs).

Layout and tiling
-----------------
Grid ``(B, H, nq, nk)`` with the KV index innermost — TPU grids iterate
sequentially, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and persists across the ``nk`` steps of one (b, h, q-block).  Each
step streams one KV tile HBM→VMEM; the [bq, bk] score tile is produced on the
MXU and never leaves VMEM.  GQA is handled in the index maps: the K/V block
for query head ``h`` is fetched from KV head ``h // group_size``, so K/V are
never materialized at H heads.

Block shapes: ``(bq, head_dim)`` / ``(bk, head_dim)`` with bq/bk multiples of
128 in production (MXU-aligned); head_dim is the lane dimension.  VMEM
working set ≈ bq·hd (q) + 2·bk·hd (kv) + bq·bk (scores) + bq·hd (acc) floats
— for bq=bk=512, hd=128: ~1.9 MB, well inside the ~16 MB/core budget while
leaving room for double-buffered pipelining.

Masking: causal and sliding-window tiles that are provably empty are skipped
via ``pl.when`` on block indices (the compiler elides the DMA + compute), so
a 500k-token causal sweep does half the work of the rectangular grid and a
windowed sweep touches only O(S·window) tiles.

Softcap (Gemma2) is applied to the score tile before masking, matching
:func:`repro.kernels.ref.attention_ref`.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_BIG_NEG = -1e30


def _attn_kernel(
    q_ref,    # [1, 1, bq, hd]
    k_ref,    # [1, 1, bk, hd]
    v_ref,    # [1, 1, bk, hd]
    o_ref,    # [1, 1, bq, hd]
    m_ref,    # VMEM [bq, 1]
    l_ref,    # VMEM [bq, 1]
    acc_ref,  # VMEM [bq, hd]
    *,
    scale: float,
    causal: bool,
    window: int,
    logit_softcap: float,
    q_offset: int,
    bq: int,
    bk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _BIG_NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this tile's queries/keys
    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip: provably-empty tiles do no DMA-dependent compute
    first_q = q_offset + iq * bq            # smallest query position in tile
    last_q = first_q + bq - 1
    first_k = ik * bk
    last_k = first_k + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= first_k <= last_q           # some key at/below the diagonal
    if window and window > 0:
        live &= last_k > first_q - window   # some key inside the window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                           # [bq, bk]
        if logit_softcap and logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        ok = jnp.ones((bq, bk), dtype=bool)
        if causal:
            ok &= k_pos <= q_pos
        if window and window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, _BIG_NEG)

        m_prev = m_ref[...]                                 # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                              # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                      # [bq, 1]
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "logit_softcap", "block_q", "block_kv",
        "q_offset", "scale", "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, T, Kv, hd]
    v: jax.Array,  # [B, T, Kv, hd]
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    q_offset: int = 0,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jax.Array:
    """Pallas fused attention.  Shapes as in :func:`repro.kernels.ref.attention_ref`."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    assert H % Kv == 0, (H, Kv)
    G = H // Kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(block_q, S)
    bk = min(block_kv, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk

    # head-major layout so the (b, h) grid axes map to leading block dims
    qt = jnp.swapaxes(q, 1, 2)  # [B, H, S, hd]
    kt = jnp.swapaxes(k, 1, 2)  # [B, Kv, T, hd]
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        logit_softcap=logit_softcap,
        q_offset=q_offset,
        bq=bq,
        bk=bk,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)  # [B, S, H, hd]
