"""Chunked Mamba-1 selective scan for TPU (``pl.pallas_call`` + BlockSpecs).

TPU adaptation (DESIGN.md §6): the CUDA kernel assigns one thread per channel
and scans time sequentially in registers.  On TPU the equivalent is a grid
over ``(batch, d_inner blocks, time chunks)`` with the per-channel state
h ∈ ℝ^{bd×ds} held in VMEM scratch; inside a chunk, a ``fori_loop`` advances
time with fully-vectorized [bd, ds] elementwise updates on the VPU while the
chunk's inputs sit in VMEM.  The diagonal-A structure of Mamba-1 makes the
update elementwise (no MXU work is lost by not using it — there is no matmul
in the recurrence), and ``y_t = C_t · h_t`` is a ds-reduction fused into the
same loop.

decay/drive (``exp(Δ·A)``, ``Δ·u·B``) are computed *inside* the kernel from
the [bd]- and [ds]-shaped chunk inputs rather than materialized at
[B, S, di, ds] in HBM — an 8–16× traffic cut versus the naive lowering, which
is exactly what makes the attention-free archs memory-bound rather than
HBM-traffic-pathological on long contexts.

VMEM per step: chunk·(2·bd + 2·ds) input floats + bd·ds state + chunk·bd out
(chunk=128, bd=256, ds=16 → ~0.4 MB).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan_pallas"]


def _scan_kernel(
    u_ref,   # [1, cs, bd]
    d_ref,   # [1, cs, bd]   delta (softplus'd)
    A_ref,   # [bd, ds]
    b_ref,   # [1, cs, ds]
    c_ref,   # [1, cs, ds]
    y_ref,   # [1, cs, bd]
    h_ref,   # VMEM [bd, ds] running state
    *,
    cs: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    u = u_ref[0].astype(jnp.float32)     # [cs, bd]
    dt = d_ref[0].astype(jnp.float32)    # [cs, bd]
    A = A_ref[...].astype(jnp.float32)   # [bd, ds]
    Bm = b_ref[0].astype(jnp.float32)    # [cs, ds]
    Cm = c_ref[0].astype(jnp.float32)    # [cs, ds]

    def step(t, carry):
        h, ys = carry
        decay = jnp.exp(dt[t][:, None] * A)                  # [bd, ds]
        drive = (dt[t] * u[t])[:, None] * Bm[t][None, :]     # [bd, ds]
        h = decay * h + drive
        y = jnp.sum(h * Cm[t][None, :], axis=-1)             # [bd]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, axis=0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros((cs, u.shape[1]), jnp.float32)
    h_fin, ys = jax.lax.fori_loop(0, cs, step, (h0, ys0))
    h_ref[...] = h_fin
    y_ref[0] = ys.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan_pallas(
    u: jax.Array,      # [B, S, di]
    delta: jax.Array,  # [B, S, di]
    A: jax.Array,      # [di, ds]
    Bmat: jax.Array,   # [B, S, ds]
    Cmat: jax.Array,   # [B, S, ds]
    *,
    chunk: int = 128,
    block_d: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Pallas selective scan; matches :func:`repro.kernels.ref.mamba_scan_ref`
    (zero initial state).  Returns y [B, S, di]."""
    B, S, di = u.shape
    ds = A.shape[1]
    cs = min(chunk, S)
    bd = min(block_d, di)
    assert S % cs == 0 and di % bd == 0, (S, cs, di, bd)
    nc = S // cs
    nd = di // bd

    kernel = functools.partial(_scan_kernel, cs=cs)

    y = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, cs, bd), lambda b, idd, ic: (b, ic, idd)),
            pl.BlockSpec((1, cs, bd), lambda b, idd, ic: (b, ic, idd)),
            pl.BlockSpec((bd, ds), lambda b, idd, ic: (idd, 0)),
            pl.BlockSpec((1, cs, ds), lambda b, idd, ic: (b, ic, 0)),
            pl.BlockSpec((1, cs, ds), lambda b, idd, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, cs, bd), lambda b, idd, ic: (b, ic, idd)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), u.dtype),
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=interpret,
    )(u, delta, A, Bmat, Cmat)
    return y
