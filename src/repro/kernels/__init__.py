"""Pallas TPU kernels for the framework's compute hot-spots.

The paper (SCISPACE) has no kernel-level contribution — these kernels exist
because the *framework's* model substrate needs them on TPU (DESIGN.md §6):

- :mod:`.flash_attention` — fused online-softmax attention (all attn archs)
- :mod:`.rwkv6_scan`      — chunked WKV recurrence (RWKV-6 "Finch")
- :mod:`.mamba_scan`      — chunked selective scan (Jamba's Mamba mixer)

Each kernel has a pure-jnp oracle in :mod:`.ref` and a jit'd dispatch wrapper
in :mod:`.ops`; tests sweep shapes/dtypes and assert_allclose kernel-vs-ref
in interpret mode (CPU container).
"""

from .ops import attention, mamba_scan, wkv6

__all__ = ["attention", "mamba_scan", "wkv6"]
