"""jax version compatibility shims.

The repo targets the mesh-context and cost-analysis surfaces that moved
between jax releases; the container pins jax 0.4.37.  Two seams matter:

- ``jax.set_mesh(mesh)`` (newer jax) vs the ``Mesh`` object's own context
  manager (0.4.x): both install the ambient mesh that ``jit``/``shard_map``
  resolve named axes against.  :func:`set_mesh` returns whichever context
  manager this jax provides.
- ``Compiled.cost_analysis()`` returns a flat dict on newer jax but a
  one-element list of dicts on 0.4.x.  :func:`cost_analysis_dict`
  normalizes to the dict (empty when XLA reports nothing).
- ``jax.shard_map`` (keyword ``axis_names``/``check_vma``) vs
  ``jax.experimental.shard_map.shard_map`` (``auto``/``check_rep``):
  :func:`shard_map` accepts the new keywords and translates.  On 0.4.x the
  vma (varying-manual-axes) type system does not exist, so
  :func:`pcast_varying` degrades to identity and replication checking is
  disabled for partial-manual regions.

Keep every jax-version branch in this module — call sites should never
probe ``jax`` themselves.
"""

from __future__ import annotations

from typing import Any, ContextManager, Dict, Optional, Set

import jax

__all__ = [
    "set_mesh",
    "cost_analysis_dict",
    "shard_map",
    "pcast_varying",
    "HAS_VMA_SHARD_MAP",
]

#: True on jax with first-class ``jax.shard_map`` + vma typing.  On 0.4.x the
#: experimental shard_map exists but its SPMD partitioner aborts (C++ check
#: ``sharding.IsManualSubgroup()``) whenever autodiff emits a while loop
#: inside a partial-manual region — any grad-of-scan or grad-inside-scan.
#: Code paths that differentiate scans under partial-manual must branch on
#: this and keep the manual region scan-free on old jax.
HAS_VMA_SHARD_MAP = hasattr(jax, "shard_map")


def set_mesh(mesh) -> ContextManager:
    """Context manager installing ``mesh`` as the ambient device mesh."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    # pre-0.5 jax: Mesh is itself the context manager
    return mesh


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` as a flat dict across jax versions."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: Optional[Set[str]] = None,
    check_vma: bool = True,
):
    """New-style ``jax.shard_map`` call shape on any supported jax.

    ``axis_names`` lists the mesh axes the region is manual over (all axes
    when None).  On 0.4.x the complement is passed as ``auto`` and
    ``check_rep`` is forced off for partial-manual regions — the old
    replication checker predates vma typing and rejects valid programs the
    new checker accepts.
    """
    new_sm = getattr(jax, "shard_map", None)
    if new_sm is not None:
        kwargs: Dict[str, Any] = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        kwargs["check_vma"] = check_vma
        return new_sm(f, **kwargs)
    from jax.experimental.shard_map import shard_map as old_sm

    manual = set(axis_names) if axis_names is not None else set(mesh.axis_names)
    auto = frozenset(set(mesh.axis_names) - manual)
    # pre-vma jax: the old rep checker needs pbroadcasts that pcast_varying
    # can no longer insert, so it must stay off regardless of check_vma
    return old_sm(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        auto=auto,
        check_rep=False,
    )


def pcast_varying(x, axes):
    """``lax.pcast(x, axes, to="varying")`` where vma typing exists; else x."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return pcast(x, axes, to="varying")
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        return pvary(x, axes)
    return x
